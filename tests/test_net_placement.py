"""Multi-rank-per-process placement over SocketTransport.

What must hold when one OS process hosts several EDAT ranks:

* co-located ranks exchange events **without touching a socket** — wire
  counters stay at zero for co-located columns while the ordinary
  sent/recv vectors show the traffic (loopback shortcutting);
* remote traffic between the same processes still flows and counts on
  the wire;
* a SIGKILLed process surfaces RANK_FAILED for **every** rank it hosted,
  at every surviving rank;
* the bootstrap placement exchange handles uneven rank/process splits.
"""
import functools
import os
import socket
import threading
import time

import pytest

import _chaos as chaos
from repro import edat
from repro.core.transport import EVENT, Message
from repro.net import SocketTransport
from repro.net.launch import (ProcessGroup, default_placement,
                              launch_processes)

pytestmark = pytest.mark.timeout(120)

PLACEMENT = {0: (0, 1), 2: (2, 3)}


def _pair_2x2(**kw):
    """Two SocketTransports, two ranks each, one socket between them."""
    a, b = socket.socketpair()
    ta = SocketTransport(0, 4, {2: a}, local_ranks=(0, 1),
                         placement=PLACEMENT, **kw)
    tb = SocketTransport(2, 4, {0: b}, local_ranks=(2, 3),
                         placement=PLACEMENT, **kw)
    return ta, tb


def _ev(src, dst, eid, data=None):
    return Message(EVENT, src, dst, edat.Event(data=data, source=src,
                                               eid=eid))


def test_default_placement_blocks():
    assert default_placement(4, 2) == [(0, 1), (2, 3)]
    assert default_placement(5, 3) == [(0, 1), (2, 3), (4,)]
    assert default_placement(3, 3) == [(0,), (1,), (2,)]


# --------------------------------------------------- transport-level unit
def test_colocated_send_is_loopback_zero_wire():
    """Events between co-located ranks land in the destination inbox with
    zero socket frames; the Mattern vectors still account for them."""
    ta, tb = _pair_2x2()
    try:
        for i in range(10):
            assert ta.send(_ev(0, 1, "co", i))
        ta.send_many([_ev(1, 0, "oc", i) for i in range(5)])
        got = [m.payload.data for m in ta.drain(1)]
        assert got == list(range(10))            # FIFO, instantly available
        assert len(ta.drain(0)) == 5
        assert ta.sent_vector()[:2] == [5, 10]   # by dst: loopback counts
        assert ta.recv_vector()[:2] == [10, 5]   # by src: both popped
        assert ta.wire_sent_vector() == [0, 0, 0, 0]   # ...but not on wire
        assert ta.wire_recv_vector() == [0, 0, 0, 0]
        assert tb.wire_recv_vector() == [0, 0, 0, 0]
    finally:
        ta.close()
        tb.close()


def test_remote_send_shares_one_socket_and_counts_wire():
    """All four cross-process (src,dst) pairs flow over the single
    process-pair connection, keep per-pair FIFO, and count as wire."""
    ta, tb = _pair_2x2()
    try:
        for i in range(8):
            assert ta.send(_ev(0, 2, "x", i))
            assert ta.send(_ev(0, 3, "x", i))
            assert ta.send(_ev(1, 3, "x", i))
        deadline = time.monotonic() + 10
        got2, got3 = [], []
        while (len(got2) + len(got3)) < 24 and time.monotonic() < deadline:
            got2 += [m for m in tb.recv_many(2, timeout=0.5)]
            got3 += [m for m in tb.drain(3)]
        assert [m.payload.data for m in got2] == list(range(8))
        by_src = {0: [], 1: []}
        for m in got3:
            by_src[m.src].append(m.payload.data)
        assert by_src[0] == list(range(8))       # per-(src,dst) FIFO
        assert by_src[1] == list(range(8))
        assert ta.wire_sent_vector() == [0, 0, 8, 16]
        assert tb.wire_recv_vector() == [16, 8, 0, 0]
        assert tb.recv_vector() == [16, 8, 0, 0]
    finally:
        ta.close()
        tb.close()


def test_mark_dead_one_colocated_rank_keeps_socket():
    """Marking ONE rank of a remote process dead must not sever the
    connection its co-located survivor still uses."""
    ta, tb = _pair_2x2()
    try:
        ta.mark_dead(3)
        assert ta.is_dead(3) and not ta.is_dead(2)
        assert not ta.send(_ev(0, 3, "x"))       # dropped
        assert ta.dropped == 1
        assert ta.send(_ev(0, 2, "x", 7))        # still flows
        deadline = time.monotonic() + 10
        got = []
        while not got and time.monotonic() < deadline:
            got = tb.recv_many(2, timeout=0.5)
        assert got[0].payload.data == 7
        ta.mark_dead(2)                          # now the whole process is
        assert not ta.send(_ev(0, 2, "y"))       # gone: socket severed
    finally:
        ta.close()
        tb.close()


def test_dead_process_reports_every_hosted_rank():
    """A crashed peer process (no BYE) yields one on_peer_dead callback
    per rank it hosted."""
    a, b = socket.socketpair()
    ta = SocketTransport(0, 4, {2: a}, local_ranks=(0, 1),
                        placement=PLACEMENT)
    tb = SocketTransport(2, 4, {0: b}, local_ranks=(2, 3),
                        placement=PLACEMENT)
    deaths = []
    ta.on_peer_dead = deaths.append
    chaos.crash_socket(b)
    chaos.wait_for(lambda: len(deaths) >= 2, 10, desc="both rank deaths")
    assert sorted(deaths) == [2, 3]
    assert ta.is_dead(2) and ta.is_dead(3)
    ta.close()
    tb.close()


# ------------------------------------ full runtimes, one process (threads)
def test_colocated_runtime_exchange_zero_wire_frames():
    """Acceptance: a 4-rank world on 2 transports — every rank streams
    events to its co-located partner AND to a remote rank.  Co-located
    columns of the wire counters must end at exactly zero while the
    event flow itself is verified by the sinks."""
    N = 40
    ta, tb = _pair_2x2()
    rts = [edat.Runtime(4, transport=ta, unconsumed="ignore"),
           edat.Runtime(4, transport=tb, unconsumed="ignore")]
    got = {r: {"co": [], "far": []} for r in range(4)}

    def main(ctx):
        partner = ctx.rank ^ 1               # co-located buddy
        far = (ctx.rank + 2) % 4             # remote process

        def co_sink(c, events):
            got[c.rank]["co"].append(events[0].data)

        def far_sink(c, events):
            got[c.rank]["far"].append(events[0].data)

        ctx.submit_persistent(co_sink, deps=[(partner, "co")])
        ctx.submit_persistent(far_sink, deps=[(far, "far")])
        for i in range(N):
            ctx.fire(partner, "co", i)
            ctx.fire(far, "far", i)

    results = [None, None]

    def go(i):
        results[i] = rts[i]._run_internal(main, timeout=60)

    ths = [threading.Thread(target=go, args=(i,)) for i in range(2)]
    for t in ths:
        t.start()
    for t in ths:
        t.join(90)
        assert not t.is_alive(), "placement run wedged"
    for r in range(4):
        assert got[r]["co"] == list(range(N))
        assert got[r]["far"] == list(range(N))
    for t in (ta, tb):
        ws, wr = t.wire_sent_vector(), t.wire_recv_vector()
        s, rv = t.sent_vector(), t.recv_vector()
        for r in t.local_ranks:
            # nothing to/from a co-located rank ever hit the socket...
            assert ws[r] == 0 and wr[r] == 0, (t.rank, ws, wr)
        for r in range(4):
            if r not in t.local_ranks:
                # ...while every remote column did
                assert ws[r] == N and wr[r] == N, (t.rank, ws, wr)
            # and the Mattern accounting covers both kinds of traffic
            assert s[r] >= N and rv[r] >= N


def test_colocated_fire_and_forget_snapshot():
    """Regression: a non-ref fire to a CO-LOCATED rank must snapshot at
    fire time.  The serialising transport's wire pickle never happens on
    the loopback path, so the runtime has to keep its defensive copy —
    mutating the payload right after ctx.fire must not be observable."""
    got = {}
    ta, tb = _pair_2x2()
    rts = [edat.Runtime(4, transport=ta, unconsumed="ignore"),
           edat.Runtime(4, transport=tb, unconsumed="ignore")]

    def main(ctx):
        if ctx.rank == 0:
            buf = {"v": [1, 2, 3]}
            ctx.fire(1, "e", buf)            # co-located, no ref
            buf["v"][:] = [99, 99, 99]       # post-fire mutation
        elif ctx.rank == 1:
            ctx.submit(lambda c, evs: got.setdefault(
                "v", list(evs[0].data["v"])), deps=[(0, "e")])

    results = [None, None]

    def go(i):
        results[i] = rts[i]._run_internal(main, timeout=30)

    ths = [threading.Thread(target=go, args=(i,)) for i in range(2)]
    for t in ths:
        t.start()
    for t in ths:
        t.join(45)
        assert not t.is_alive()
    assert got["v"] == [1, 2, 3], "loopback leaked the live payload"


# ------------------------------------------------- real spawned processes
_READY_RANK = 3


def _placement_kill_main(ctx, ready_path="", out_dir=""):
    """4 ranks / 2 procs: the victim process (ranks 2,3) stalls; each
    surviving rank writes a marker file once it has seen RANK_FAILED for
    BOTH hosted ranks of the victim."""
    seen = set()

    def on_fail(c, events):
        seen.add(events[0].data)
        if seen == {2, 3}:
            open(os.path.join(out_dir, f"failed_seen_{c.rank}"),
                 "w").close()

    ctx.submit_persistent(on_fail, deps=[(edat.ANY, edat.RANK_FAILED)])
    if ctx.rank == _READY_RANK:
        open(ready_path, "w").close()
        time.sleep(300)          # never finishes: must be SIGKILLed


def test_killed_process_surfaces_rank_failed_for_all_hosted_ranks(tmp_path):
    """SIGKILL one process of a 4-rank/2-process world: both survivors
    must observe RANK_FAILED for *both* ranks the victim hosted, then
    terminate cleanly."""
    ready = str(tmp_path / "ready")
    pg = ProcessGroup(
        4, functools.partial(_placement_kill_main, ready_path=ready,
                             out_dir=str(tmp_path)),
        n_procs=2, run_timeout=60, hb_interval=0.2, hb_timeout=1.5)
    pg.start()
    chaos.sigkill_when_ready(pg, 2, ready, timeout=60, settle=0.3)
    stats = pg.wait(60)
    codes = pg.exitcodes()
    assert codes[2] != 0 and codes[3] != 0       # the victim pair
    assert codes[0] == 0 and codes[1] == 0       # survivors exited clean
    for r in (0, 1):
        assert os.path.exists(str(tmp_path / f"failed_seen_{r}")), \
            f"rank {r} did not see RANK_FAILED for both hosted ranks"
    # 2 RANK_FAILED handler runs per surviving rank
    assert stats["tasks_executed"] == 4


def _ring_main(ctx, n_hops=60):
    left = (ctx.rank - 1) % ctx.n_ranks

    def relay(c, events):
        if events[0].data < n_hops:
            c.fire((c.rank + 1) % c.n_ranks, "token", events[0].data + 1)

    ctx.submit_persistent(relay, deps=[(left, "token")])
    if ctx.rank == 0:
        ctx.fire(1, "token", 1)


def test_uneven_placement_spawned_ring():
    """5 ranks over 3 processes (blocks (0,1)(2,3)(4,)): the rendezvous
    exchanges the placement and the ring crosses both loopback and
    socket hops."""
    stats = launch_processes(5, functools.partial(_ring_main, n_hops=60),
                             n_procs=3, timeout=60)
    assert stats["events_sent"] == stats["events_received"] == 60
    assert stats["tasks_executed"] == 60
