"""Property/fuzz tests for the repro.net frame codec.

The decoding contract (relied on by SocketTransport's reader threads):

* anything encoded by ``encode`` / ``encode_batch`` roundtrips exactly;
* a truncated stream / mid-frame EOF decodes to ``None`` (socket paths)
  or leaves the partial frame unconsumed (``decode_buffer``);
* a garbage length header or corrupt body raises (socket paths) or flags
  ``corrupt`` (``decode_buffer``) — decoders NEVER hang a reader thread
  on a complete-but-bad byte stream.

Property tests use hypothesis when installed (``_hypothesis_optional``);
the seeded-random fuzz tests below run everywhere.
"""
import io
import pickle
import random
import socket
import struct
import threading

import numpy as np
import pytest
from _hypothesis_optional import given, settings, st

from repro.net import frames


def _cat(pieces) -> bytes:
    return b"".join(bytes(p) for p in pieces)


def _roundtrip_batch(objs, oob=True):
    blob = _cat(frames.encode_batch(objs, oob=oob))
    decoded, used, corrupt = frames.decode_buffer(bytearray(blob))
    assert not corrupt and used == len(blob)
    assert len(decoded) == 1
    kind, got = decoded[0]
    assert kind == frames.MSGS
    return got


# ------------------------------------------------------------- roundtrips
def test_plain_frame_roundtrip_over_socket():
    a, b = socket.socketpair()
    try:
        payload = ("msg", {"x": [1, 2.5, "s"], "y": None})
        frames.send_frame(a, payload)
        assert frames.recv_frame(b) == payload
    finally:
        a.close()
        b.close()


def test_batch_frame_roundtrip_inband_and_oob():
    objs = [{"i": i, "arr": np.arange(i + 1, dtype=np.int64)}
            for i in range(5)]
    for oob in (True, False):
        got = _roundtrip_batch(objs, oob=oob)
        assert len(got) == len(objs)
        for o, g in zip(objs, got):
            assert g["i"] == o["i"]
            np.testing.assert_array_equal(g["arr"], o["arr"])


def test_batch_oob_arrays_decode_writable():
    """Zero-copy out-of-band numpy payloads must reconstruct as *writable*
    arrays (they are views over the mutable receive buffer)."""
    arr = np.arange(100, dtype=np.float64)
    (got,) = _roundtrip_batch([arr], oob=True)
    np.testing.assert_array_equal(got, arr)
    got[:] = -1.0  # raises ValueError if the buffer came back read-only


def test_batch_oob_noncontiguous_falls_back():
    arr = np.arange(64, dtype=np.int64).reshape(8, 8).T  # not C-contiguous
    (got,) = _roundtrip_batch([arr], oob=True)
    np.testing.assert_array_equal(got, arr)


def test_batch_roundtrip_over_socket_and_buffered():
    objs = [np.arange(4), "text", 7]
    blob = _cat(frames.encode_batch(objs))
    a, b = socket.socketpair()
    try:
        a.sendall(blob)
        kind, got = frames.recv_frame(b)
        assert kind == frames.MSGS and got[1] == "text" and got[2] == 7
        np.testing.assert_array_equal(got[0], objs[0])
    finally:
        a.close()
        b.close()
    kind, got = frames.recv_frame_buffered(io.BytesIO(blob))
    assert kind == frames.MSGS and len(got) == 3


def test_decode_buffer_many_mixed_frames():
    objs = list(range(10))
    blob = (frames.encode(("hb",))
            + _cat(frames.encode_batch(objs))
            + frames.encode(("msg", "single"))
            + frames.encode(("bye",)))
    decoded, used, corrupt = frames.decode_buffer(bytearray(blob))
    assert not corrupt and used == len(blob)
    assert [d[0] for d in decoded] == ["hb", frames.MSGS, "msg", "bye"]
    assert decoded[1][1] == objs


# ----------------------------------------------- truncation / garbage input
def test_truncated_stream_returns_none():
    blob = frames.encode(("msg", list(range(100))))
    for cut in (1, 3, 4, 10, len(blob) - 1):
        a, b = socket.socketpair()
        try:
            a.sendall(blob[:cut])
            a.close()  # EOF mid-frame
            assert frames.recv_frame(b) is None
        finally:
            b.close()
        assert frames.recv_frame_buffered(io.BytesIO(blob[:cut])) is None


def test_decode_buffer_leaves_partial_frame_unconsumed():
    blob = _cat(frames.encode_batch([np.arange(50)]))
    for cut in (0, 1, 4, 20, len(blob) - 1):
        decoded, used, corrupt = frames.decode_buffer(bytearray(blob[:cut]))
        assert decoded == [] and used == 0 and not corrupt
    # completing the buffer then decodes exactly one frame
    decoded, used, corrupt = frames.decode_buffer(bytearray(blob))
    assert len(decoded) == 1 and used == len(blob) and not corrupt


def test_garbage_length_header_raises_not_hangs():
    huge = struct.pack(">I", frames.MAX_FRAME + 1) + b"x" * 16
    with pytest.raises(ValueError):
        frames.recv_frame_buffered(io.BytesIO(huge))
    a, b = socket.socketpair()
    try:
        a.sendall(huge)
        with pytest.raises(ValueError):
            frames.recv_frame(b)
    finally:
        a.close()
        b.close()
    _, _, corrupt = frames.decode_buffer(bytearray(huge))
    assert corrupt


def test_corrupt_body_flags_not_hangs():
    # well-formed header, garbage pickle body
    bad = struct.pack(">I", 8) + b"\xde\xad\xbe\xef\xde\xad\xbe\xef"
    decoded, used, corrupt = frames.decode_buffer(bytearray(bad))
    assert corrupt and decoded == []
    # batch bit set, garbage buffer table (claims absurd buffer count)
    body = struct.pack(">I", 0xFFFFFF) + b"z" * 12
    bad2 = struct.pack(">I", len(body) | frames.BATCH_BIT) + body
    decoded, used, corrupt = frames.decode_buffer(bytearray(bad2))
    assert corrupt
    with pytest.raises(Exception):
        frames.recv_frame_buffered(io.BytesIO(bad2))


def test_reader_never_hangs_on_partial_then_close():
    """A reader blocked mid-frame must return (None) promptly when the
    peer goes away — this is what keeps SocketTransport reader threads
    from wedging on a crashed sender."""
    a, b = socket.socketpair()
    out = []

    def read():
        out.append(frames.recv_frame(b))

    t = threading.Thread(target=read, daemon=True)
    t.start()
    a.sendall(struct.pack(">I", 1000) + b"partial")
    a.shutdown(socket.SHUT_RDWR)
    a.close()
    t.join(5.0)
    assert not t.is_alive(), "reader wedged on mid-frame EOF"
    assert out == [None]
    b.close()


# ------------------------------------------------------- seeded random fuzz
def _random_payload(rng: random.Random, depth=0):
    kind = rng.randrange(7 if depth < 2 else 5)
    if kind == 0:
        return rng.randrange(-10**9, 10**9)
    if kind == 1:
        return rng.random()
    if kind == 2:
        return "".join(chr(rng.randrange(32, 0x2FF))
                       for _ in range(rng.randrange(20)))
    if kind == 3:
        return bytes(rng.randrange(256) for _ in range(rng.randrange(30)))
    if kind == 4:
        dt = rng.choice([np.int8, np.int64, np.float32, np.float64])
        return (np.arange(rng.randrange(1, 200)).astype(dt)
                if rng.random() < 0.5 else
                np.frombuffer(bytes(rng.randrange(256)
                                    for _ in range(8 * 8)), np.float64))
    if kind == 5:
        return [_random_payload(rng, depth + 1)
                for _ in range(rng.randrange(4))]
    return {f"k{i}": _random_payload(rng, depth + 1)
            for i in range(rng.randrange(4))}


def _eq(a, b):
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return (np.asarray(a).dtype == np.asarray(b).dtype
                and np.array_equal(np.asarray(a), np.asarray(b)))
    if isinstance(a, list):
        return (isinstance(b, list) and len(a) == len(b)
                and all(_eq(x, y) for x, y in zip(a, b)))
    if isinstance(a, dict):
        return (isinstance(b, dict) and a.keys() == b.keys()
                and all(_eq(v, b[k]) for k, v in a.items()))
    return a == b


@pytest.mark.parametrize("seed", range(5))
def test_fuzz_roundtrip_random_payloads_random_chunking(seed):
    """Arbitrary payload trees, mixed frame kinds, delivered to the
    incremental decoder in random-sized chunks (simulating TCP segmenting)
    must reproduce the exact frame sequence."""
    rng = random.Random(seed)
    sent = []
    wire = bytearray()
    for _ in range(30):
        if rng.random() < 0.5:
            objs = [_random_payload(rng) for _ in range(rng.randrange(1, 6))]
            sent.append((frames.MSGS, objs))
            wire += _cat(frames.encode_batch(objs,
                                             oob=rng.random() < 0.7))
        else:
            obj = ("msg", _random_payload(rng))
            sent.append(obj)
            wire += frames.encode(obj)
    got = []
    buf = bytearray()
    i = 0
    while i < len(wire) or buf:
        step = rng.randrange(1, 4096)
        buf += wire[i:i + step]
        i += step
        decoded, used, corrupt = frames.decode_buffer(buf)
        assert not corrupt
        del buf[:used]
        got.extend(decoded)
        if i >= len(wire) and not decoded and used == 0:
            break
    assert len(got) == len(sent)
    for g, s in zip(got, sent):
        assert g[0] == s[0]
        assert _eq(list(g[1]) if g[0] == frames.MSGS else g[1],
                   list(s[1]) if s[0] == frames.MSGS else s[1])


@pytest.mark.parametrize("seed", range(5))
def test_fuzz_garbage_never_hangs_or_crashes_decoder(seed):
    """Pure noise (and noise spliced into valid traffic) must terminate
    the decoder with corrupt=True or partial-wait — never an unhandled
    exception, never an infinite loop."""
    rng = random.Random(1000 + seed)
    for _ in range(50):
        junk = bytearray(rng.randrange(256)
                         for _ in range(rng.randrange(1, 2000)))
        if rng.random() < 0.3:  # splice junk after a valid frame
            junk = bytearray(frames.encode(("hb",))) + junk
        decoded, used, corrupt = frames.decode_buffer(junk)
        assert used <= len(junk)
        assert corrupt or used == 0 or decoded  # progressed or waiting


# ---------------------------------------------------- hypothesis properties
@given(st.lists(st.one_of(st.integers(), st.text(), st.booleans(),
                          st.floats(allow_nan=False),
                          st.binary(max_size=64)),
                max_size=20))
@settings(max_examples=50, deadline=None)
def test_property_batch_roundtrip(objs):
    for oob in (True, False):
        got = _roundtrip_batch(objs, oob=oob)
        assert got == objs


@given(st.binary(max_size=512))
@settings(max_examples=100, deadline=None)
def test_property_arbitrary_bytes_never_hang(data):
    decoded, used, corrupt = frames.decode_buffer(bytearray(data))
    assert used <= len(data)
