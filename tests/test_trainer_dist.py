"""The elastic trainer running *distributed* through the v2 Session API:
real spawned OS processes, several ranks per process, gradient exchange
over the coalescing SocketTransport — and SIGKILL-grade fault tolerance.

Acceptance-grade checks:

* 4 ranks across 2 processes train to completion and every rank's final
  parameters equal an in-proc (threads-as-ranks) run of the same config
  — the transport is genuinely transparent to the numerics;
* SIGKILL one process mid-run: the survivors (the two ranks co-located
  in the other process) detect the failure via the transport heartbeat,
  roll back to the last durable checkpoint on the shared ``ckpt_dir``,
  re-shard, finish — and their final parameters match an uninterrupted
  in-proc run of the *same elastic schedule* (4 ranks to the recovery
  step, then 2 ranks to the end), the same rollback semantics
  ``test_node_failure_recovery_elastic`` verifies in-proc.

Determinism note: the quorum collector folds gradients in rank order
(see test_quorum.py), data shards are pure functions of
(step, shard, n_shards), and replicas share the seed — so the
distributed and in-proc runs are numerically interchangeable and the
comparisons below can be tight.
"""
import numpy as np
import pytest

import _chaos as chaos
from repro import edat
from repro.checkpoint import latest_step
from repro.data import DataCfg
from repro.models import ModelCfg
from repro.optim import OptCfg
from repro.runtime_dist import (EventDrivenTrainer, TrainerCfg,
                                flatten_params, trainer_program)

pytestmark = pytest.mark.timeout(600)

TINY = ModelCfg(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab=128,
    dtype="float32", remat="none", max_target_length=64,
)
DATA = DataCfg(vocab=128, seq=32, global_batch=12, seed=7)
OPT = OptCfg(name="adamw", peak_lr=3e-2, warmup=5, total_steps=200,
             clip_norm=1.0)


def _inproc(**kw):
    from repro.models import build_model
    tc = TrainerCfg(steps=kw.pop("steps", 12), n_ranks=kw.pop("n_ranks", 2),
                    **kw)
    return EventDrivenTrainer(build_model(TINY), DATA, OPT, tc)


def _assert_params_close(flat_a, flat_b, rtol=1e-5, atol=1e-6):
    assert sorted(flat_a) == sorted(flat_b)
    for k in flat_a:
        np.testing.assert_allclose(flat_a[k], flat_b[k], rtol=rtol,
                                   atol=atol, err_msg=k)


def test_distributed_trainer_matches_inproc(tmp_path):
    """No faults: 4 ranks / 2 processes over sockets == 4 threads-as-ranks
    in one process, final params compared rank by rank."""
    steps = 6
    cfg = TrainerCfg(steps=steps, n_ranks=4, collect_timeout=60.0)
    with edat.Session(4, procs=2, transport="socket", timeout=300.0,
                      workers_per_rank=cfg.workers_per_rank,
                      unconsumed="ignore") as s:
        s.run(edat.deferred(trainer_program, TINY, DATA, OPT, cfg))
        res = s.gather()
    assert sorted(res["final_params"]) == [0, 1, 2, 3]
    assert max(m["step"] for m in res["history"]) >= steps
    # sync quorum: every recorded step consumed all 4 replicas' grads
    assert all(m["n_grads"] == 4 for m in res["history"])

    out = _inproc(steps=steps, n_ranks=4, collect_timeout=60.0).run()
    ref = flatten_params(out["final_params"][0])
    for r in range(4):
        _assert_params_close(res["final_params"][r], ref)


def test_distributed_sigkill_recovery_matches_inproc_elastic(tmp_path):
    """THE capstone (paper §VII): 4 ranks / 2 processes, SIGKILL the
    process hosting ranks 2+3 once a real checkpoint exists.  The
    co-located survivors must recover from the shared on-disk checkpoint
    and finish — and match an uninterrupted in-proc run of the same
    elastic schedule (4 ranks to the recovery step R, 2 ranks from R)."""
    steps, every = 12, 3
    ckdir = str(tmp_path / "ck")
    cfg = TrainerCfg(steps=steps, n_ranks=4, ckpt_dir=ckdir,
                     ckpt_every=every, collect_timeout=30.0)
    with edat.Session(4, procs=2, transport="socket", timeout=300.0,
                      workers_per_rank=cfg.workers_per_rank,
                      unconsumed="ignore", hb_interval=0.2,
                      hb_timeout=1.5) as s:
        s.start(edat.deferred(trainer_program, TINY, DATA, OPT, cfg))
        # SIGKILL-at-phase: wait (from outside, via the shared ckpt dir)
        # for the first real checkpoint — the rollback anchor — then kill
        chaos.wait_for(lambda: (latest_step(ckdir) or 0) >= every, 240,
                       desc="first periodic checkpoint")
        s.kill(3)
        s.wait(300, check=False)
        codes = s.exitcodes()
        res = s.gather()
    assert codes[2] != 0 and codes[3] != 0        # the victim pair
    assert codes[0] == 0 and codes[1] == 0        # survivors finished

    hist = res["history"]
    assert max(m["step"] for m in hist) >= steps
    # exactly one coordinated recovery per survivor (the per-hosted-rank
    # RANK_FAILED events were swept into a single rollback)
    recs = res["recoveries"]
    assert sorted(r["rank"] for r in recs) == [0, 1], recs
    assert len({(r["step"], r["epoch"]) for r in recs}) == 1, recs
    R = recs[0]["step"]
    assert R >= every and R % every == 0
    # survivors re-sharded: the elastic tail ran on 2-rank quorums
    tail = [m for m in hist if m["step"] > steps - 2]
    assert tail and all(m["n_grads"] == 2 for m in tail)
    assert sorted(res["final_params"]) == [0, 1]  # the dead never report

    # ---- uninterrupted in-proc reference of the same elastic schedule
    refck = str(tmp_path / "refck")
    # phase 1: the 4-rank prefix up to the recovery step R (checkpointing
    # on the same cadence, so refck holds the same step-R checkpoint the
    # survivors rolled back to)
    out_a = _inproc(steps=R, n_ranks=4, ckpt_dir=refck, ckpt_every=every,
                    collect_timeout=30.0).run()
    assert latest_step(refck) == R
    # phase 2: resume from R with the survivor set (2 ranks, re-sharded)
    out_b = _inproc(steps=steps, n_ranks=2, ckpt_dir=refck,
                    start_step=R, ckpt_every=10_000,
                    collect_timeout=30.0).run()
    assert max(m["step"] for m in out_b["history"]) >= steps
    ref = flatten_params(out_b["final_params"][0])
    for r in (0, 1):
        _assert_params_close(res["final_params"][r], ref)
