"""Paper-fidelity tests for the EDAT core runtime (paper §II, §IV).

Each test encodes a guarantee stated in the paper; listing numbers refer to
the paper's code listings.
"""
import threading
import time

import pytest

from repro import edat


def run(n_ranks, main, workers=2, timeout=30.0, **kw):
    with edat.Session(n_ranks, workers_per_rank=workers, timeout=timeout,
                      **kw) as s:
        stats = s.run(main)
    return s, stats


# ---------------------------------------------------------------- Listing 4
def test_listing4_simple_example():
    """The paper's end-to-end example: 3 tasks across 2 ranks."""
    out = []

    def task1(ctx, events):
        ctx.fire(1, "event1")                # no payload
        ctx.fire(1, "event2", 33)            # single int payload

    def task2(ctx, events):
        ctx.fire(edat.SELF, "event3", 100)

    def task3(ctx, events):
        out.append(events[0].data + events[1].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(task1)
        elif ctx.rank == 1:
            ctx.submit(task2, deps=[(0, "event1")])
            ctx.submit(task3, deps=[(0, "event2"), (1, "event3")])

    _, stats = run(2, main)
    assert out == [133]
    assert stats["tasks_executed"] == 3
    assert stats["events_sent"] == stats["events_received"] == 3


# ------------------------------------------------------------- §II.B orders
def test_src_dst_fifo_ordering():
    """Events from one src to one dst arrive in fire order (§II.B)."""
    N = 200
    got = []

    def consumer(ctx, events):
        got.append(events[0].data)

    def main(ctx):
        if ctx.rank == 1:
            for _ in range(N):
                ctx.submit(consumer, deps=[(0, "seq")])
        else:
            for i in range(N):
                ctx.fire(1, "seq", i)

    run(2, main)
    assert got == list(range(N))


def test_task_submission_precedence():
    """Earlier-submitted tasks have precedence in consuming events (§II.B)."""
    got = []

    def mk(tag):
        def t(ctx, events):
            got.append((tag, events[0].data))
        return t

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(mk("first"), deps=[(edat.SELF, "e")])
            ctx.submit(mk("second"), deps=[(edat.SELF, "e")])
            ctx.fire(edat.SELF, "e", 1)
            ctx.fire(edat.SELF, "e", 2)

    run(1, main)
    assert sorted(got) == [("first", 1), ("second", 2)]


def test_events_delivered_in_dependency_order():
    """The events array matches the declared dependency order, not arrival
    order (§II.A)."""
    seen = {}

    def t(ctx, events):
        seen["eids"] = [e.eid for e in events]
        seen["data"] = [e.data for e in events]

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(t, deps=[(edat.SELF, "a"), (edat.SELF, "b"),
                                (edat.SELF, "c")])
            ctx.fire(edat.SELF, "c", 3)
            ctx.fire(edat.SELF, "b", 2)
            ctx.fire(edat.SELF, "a", 1)

    run(1, main)
    assert seen["eids"] == ["a", "b", "c"]
    assert seen["data"] == [1, 2, 3]


def test_fire_and_forget_payload_copy():
    """Payload is copied at fire time; later mutation is invisible (§II.B)."""
    import numpy as np
    got = {}

    def t(ctx, events):
        got["v"] = events[0].data.copy()

    def main(ctx):
        if ctx.rank == 0:
            buf = np.array([1, 2, 3])
            ctx.fire(edat.SELF, "e", buf)
            buf[:] = 99  # mutate after fire: must not be observed
            ctx.submit(t, deps=[(edat.SELF, "e")])

    run(1, main)
    assert list(got["v"]) == [1, 2, 3]


def test_events_before_task_submission_are_stored():
    """Events may arrive before the consuming task is submitted."""
    got = []

    def t(ctx, events):
        got.append(events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.fire(1, "e", 42)
        else:
            time.sleep(0.05)
            ctx.submit(t, deps=[(0, "e")])

    run(2, main)
    assert got == [42]


# --------------------------------------------------------------- wildcards
def test_any_source_wildcard():
    got = []

    def t(ctx, events):
        got.append(events[0].source)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(t, deps=[(edat.ANY, "e")])
            ctx.submit(t, deps=[(edat.ANY, "e")])
        else:
            ctx.fire(0, "e", ctx.rank)

    run(3, main)
    assert sorted(got) == [1, 2]


def test_all_reduction_listing5():
    """Paper Listing 5: task depending on an event from ALL ranks."""
    total = []

    def t(ctx, events):
        total.append(sum(e.data for e in events))
        # events ordered by rank (documented determinism)
        assert [e.source for e in events] == list(range(ctx.n_ranks))

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(t, deps=[(edat.ALL, "event")])
        ctx.fire(0, "event", ctx.rank + 1)

    run(4, main)
    assert total == [1 + 2 + 3 + 4]


def test_all_broadcast_and_barrier_listing6():
    """Paper Listing 6: EDAT_ALL fire + EDAT_ALL dep = non-blocking barrier."""
    hits = []

    def barrier_task(ctx, events):
        hits.append(ctx.rank)

    def main(ctx):
        ctx.submit(barrier_task, deps=[(edat.ALL, "b")])
        ctx.fire(edat.ALL, "b")

    run(3, main)
    assert sorted(hits) == [0, 1, 2]


# ----------------------------------------------------------- §IV persistent
def test_persistent_task_runs_many_times():
    got = []

    def t(ctx, events):
        got.append(events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(t, deps=[(1, "e")], name="p")
        else:
            for i in range(5):
                ctx.fire(0, "e", i)

    run(2, main)
    assert sorted(got) == [0, 1, 2, 3, 4]


def test_persistent_task_multiple_frames_in_flight():
    """§IV.A: multiple partially-filled copies of a persistent task."""
    got = []

    def t(ctx, events):
        got.append((events[0].data, events[1].data))

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(t, deps=[(edat.SELF, "a"),
                                           (edat.SELF, "b")])
            # fire three a's, then three b's: frames pair them FIFO
            for i in range(3):
                ctx.fire(edat.SELF, "a", i)
            for i in range(3):
                ctx.fire(edat.SELF, "b", 10 + i)

    run(1, main)
    assert sorted(got) == [(0, 10), (1, 11), (2, 12)]


def test_persistent_event_refires_locally():
    """§IV.A: a persistent event re-fires once consumed."""
    got = []

    def t(ctx, events):
        got.append(events[0].data)
        if len(got) < 3:
            # consume it again via another transitory task
            ctx.submit(t, deps=[(edat.SELF, "pe")])

    def main(ctx):
        ctx.fire(edat.SELF, "pe", 7, persistent=True)
        ctx.submit(t, deps=[(edat.SELF, "pe")])

    run(1, main, unconsumed="ignore")
    assert got == [7, 7, 7]


def test_remove_named_persistent_task():
    got = []

    def t(ctx, events):
        got.append(events[0].data)

    def main(ctx):
        ctx.submit_persistent(t, deps=[(edat.SELF, "e")], name="worker")
        ctx.fire(edat.SELF, "e", 1)
        time.sleep(0.2)
        assert ctx.remove_task("worker")
        ctx.fire(edat.SELF, "e", 2)  # nobody consumes -> would be unconsumed

    run(1, main, unconsumed="ignore")
    assert got == [1]


# -------------------------------------------------------------- wait / poll
def test_wait_pauses_and_resumes_with_context():
    got = {}

    def t(ctx, events):
        local = events[0].data * 10          # local context preserved
        more = ctx.wait([(1, "late")])
        got["v"] = local + more[0].data

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(t, deps=[(1, "early")])
        else:
            ctx.fire(0, "early", 5)
            time.sleep(0.1)
            ctx.fire(0, "late", 3)

    run(2, main)
    assert got["v"] == 53


def test_wait_frees_worker_for_other_tasks():
    """With ONE worker, a task blocked in wait() must not starve the task
    that will satisfy it (paper: task switched out, worker freed)."""
    order = []

    def waiter(ctx, events):
        order.append("wait-start")
        ctx.wait([(edat.SELF, "unblock")])
        order.append("wait-end")

    def unblocker(ctx, events):
        order.append("unblock")
        ctx.fire(edat.SELF, "unblock")

    def main(ctx):
        ctx.submit(waiter)
        ctx.submit(unblocker)

    run(1, main, workers=1)
    assert order == ["wait-start", "unblock", "wait-end"]


def test_retrieve_any_nonblocking_subset():
    got = {}

    def t(ctx, events):
        # x was fired before this task; y comes 0.1s later. retrieve_any
        # never blocks: poll until x shows up, observing y absent meanwhile.
        first = []
        while not first:
            first = ctx.retrieve_any([(edat.SELF, "x"), (edat.SELF, "y")])
            time.sleep(0.005)
        got["first"] = sorted(e.eid for e in first)
        while True:
            more = ctx.retrieve_any([(edat.SELF, "y")])
            if more:
                got["second"] = more[0].data
                break
            time.sleep(0.005)

    def main(ctx):
        ctx.fire(edat.SELF, "x", 1)
        ctx.submit(t)
        time.sleep(0.1)
        ctx.fire(edat.SELF, "y", 2)

    run(1, main, workers=2)
    assert got["first"] == ["x"]
    assert got["second"] == 2


# ------------------------------------------------------------------- locks
def test_locks_mutual_exclusion_and_autorelease():
    counter = {"v": 0, "max_conc": 0, "conc": 0}
    mu = threading.Lock()

    def t(ctx, events):
        ctx.lock("L")                       # auto-released at task end
        with mu:
            counter["conc"] += 1
            counter["max_conc"] = max(counter["max_conc"], counter["conc"])
        v = counter["v"]
        time.sleep(0.002)
        counter["v"] = v + 1
        with mu:
            counter["conc"] -= 1

    def main(ctx):
        for _ in range(8):
            ctx.submit(t)

    run(1, main, workers=4)
    assert counter["v"] == 8
    assert counter["max_conc"] == 1         # lock enforced mutual exclusion


def test_test_lock_nonblocking():
    res = {}

    def t1(ctx, events):
        ctx.lock("L")
        ctx.fire(edat.SELF, "locked")
        ctx.wait([(edat.SELF, "done")])     # wait releases L (paper §IV.C)
        res["t1_reacquired"] = ctx.test_lock("L")

    def t2(ctx, events):
        # t1 parked in wait -> lock was released.  "locked" is fired before
        # t1 enters wait(), so poll briefly: t1 may not have parked yet.
        res["while_held"] = False
        for _ in range(400):
            if ctx.test_lock("L"):
                res["while_held"] = True
                ctx.unlock("L")
                break
            time.sleep(0.005)
        ctx.fire(edat.SELF, "done")

    def main(ctx):
        ctx.submit(t1)
        ctx.submit(t2, deps=[(edat.SELF, "locked")])

    run(1, main, workers=2)
    assert res["while_held"] is True        # released across wait
    assert res["t1_reacquired"] is True     # reacquired on resume


def test_listing10_mutex_via_events():
    """Paper Listing 10: persistent task + self-event = mutual exclusion."""
    state = {"v": 0, "conc": 0, "max_conc": 0}
    N = 6

    def task(ctx, events):
        state["conc"] += 1
        state["max_conc"] = max(state["max_conc"], state["conc"])
        v = state["v"]
        time.sleep(0.002)
        state["v"] = v + events[1].data
        state["conc"] -= 1
        ctx.fire(edat.SELF, "data", events[0].data, ref=True)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(task, deps=[(edat.SELF, "data"),
                                              (1, "values")], name="upd")
            shared = {"buf": [0] * 10}
            ctx.fire(edat.SELF, "data", shared, ref=True)
        else:
            for _ in range(N):
                ctx.fire(0, "values", 1)

    def main2(ctx):
        main(ctx)
        if ctx.rank == 0:
            # once all N updates landed, retire the persistent task; its
            # partially-filled frame (holding the last "data" event) is
            # discarded with it (§IV.A named-task removal)
            while state["v"] < N:
                time.sleep(0.01)
            assert ctx.remove_task("upd")

    # run with enough workers that unsafe interleaving WOULD occur.
    # unconsumed="ignore": remove_task races the final instance's re-fire of
    # the "data" token, which may then be stored with no consumer left —
    # an expected leftover of §IV.A named-task removal, not a test failure
    run(2, main2, workers=4, timeout=60, unconsumed="ignore")
    assert state["v"] == N
    assert state["max_conc"] == 1


# ------------------------------------------------------------- termination
def test_termination_waits_for_inflight_events():
    """§II.E conditions 3+4: termination only after delivery+consumption."""
    got = []

    def t(ctx, events):
        time.sleep(0.05)
        got.append(events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(t, deps=[(1, "e")])
        else:
            for i in range(3):
                time.sleep(0.03)
                ctx.fire(0, "e", i)

    run(2, main)
    assert got == [0, 1, 2]


def test_deadlock_detected_unmet_task():
    def t(ctx, events):  # pragma: no cover - never runs
        pass

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(t, deps=[(1, "never")])

    with pytest.raises(edat.EdatDeadlockError):
        run(2, main, timeout=20)


def test_unconsumed_event_detected():
    def main(ctx):
        if ctx.rank == 0:
            ctx.fire(edat.SELF, "stray", 1)

    with pytest.raises(edat.EdatDeadlockError):
        run(1, main, timeout=20)
    run(1, main, timeout=20, unconsumed="ignore")  # opt-out works


def test_task_exception_propagates():
    def t(ctx, events):
        raise ValueError("boom")

    def main(ctx):
        ctx.submit(t)

    with pytest.raises(edat.EdatTaskError, match="boom"):
        run(1, main)


# ------------------------------------------------------------------- misc
def test_nested_task_submission():
    got = []

    def inner(ctx, events):
        got.append("inner")

    def outer(ctx, events):
        got.append("outer")
        ctx.submit(inner)

    def main(ctx):
        ctx.submit(outer)

    run(1, main)
    assert got == ["outer", "inner"]


def test_duplicate_dependency_two_slots():
    got = []

    def t(ctx, events):
        got.append([e.data for e in events])

    def main(ctx):
        ctx.submit(t, deps=[(edat.SELF, "e"), (edat.SELF, "e")])
        ctx.fire(edat.SELF, "e", 1)
        ctx.fire(edat.SELF, "e", 2)

    run(1, main)
    assert got == [[1, 2]]


def test_fire_batch_fifo_and_targets():
    """fire_batch: per-(src,dst) FIFO across the batch; SELF/ALL targets and
    payload-copy semantics identical to single fire."""
    import numpy as np
    got = []
    bcast = []

    def sink(ctx, events):
        got.append(events[0].data if not isinstance(events[0].data,
                                                    np.ndarray)
                   else list(events[0].data))

    def btask(ctx, events):
        bcast.append(ctx.rank)

    def main(ctx):
        ctx.submit(btask, deps=[(0, "b")])
        if ctx.rank == 1:
            for i in range(50):
                ctx.submit(sink, deps=[(0, "seq")])
        elif ctx.rank == 0:
            buf = np.array([7])
            ctx.fire_batch(
                [(1, "seq", i) for i in range(49)]
                + [(1, "seq", buf), (edat.ALL, "b")])
            buf[:] = 0  # mutation after fire_batch must not be observed

    run(2, main)
    assert got == list(range(49)) + [[7]]
    assert sorted(bcast) == [0, 1]


def test_timer_cancel_before_firing():
    """cancel() before the deadline: True, and the event never fires."""
    res = {}

    def t(ctx, events):  # pragma: no cover - must not run
        res["fired"] = True

    def main(ctx):
        if ctx.rank == 0:
            h = ctx.fire_after(5.0, edat.SELF, "never")
            ctx.submit(t, deps=[(edat.SELF, "never")])
            res["cancelled"] = h.cancel()
            res["again"] = h.cancel()      # second cancel: already cancelled

    s = edat.Session(1, workers_per_rank=2)
    t0 = time.monotonic()
    with pytest.raises(edat.EdatDeadlockError):
        # the task's dep can never be met once the timer is cancelled
        s.run(main, timeout=20)
    assert res.get("cancelled") is True
    assert res.get("again") is False
    assert "fired" not in res
    # a cancelled timer no longer delays quiescence until its deadline
    assert time.monotonic() - t0 < 4.0


def test_timer_cancel_after_firing_returns_false():
    res = {}

    def t(ctx, events):
        res["fired"] = True

    def main(ctx):
        if ctx.rank == 0:
            h = ctx.fire_after(0.05, edat.SELF, "tick")
            ctx.submit(t, deps=[(edat.SELF, "tick")])
            time.sleep(0.3)
            res["cancelled"] = h.cancel()

    run(1, main)
    assert res["fired"] is True
    assert res["cancelled"] is False      # too late: the timer already fired


def test_reentrant_lock_recorded_and_autoreleased():
    """A reentrant lock acquisition is recorded in the task's lock set, so
    it is auto-released at task end (paper §IV.C)."""
    res = {}

    def t1(ctx, events):
        ctx.lock("L")
        ctx.lock("L")                      # reentrant: still held once
        ctx.fire(edat.SELF, "go")
        # NO explicit unlock: auto-release at task end must free it

    def t2(ctx, events):
        res["acquired"] = ctx.test_lock("L")
        if res["acquired"]:
            ctx.unlock("L")

    def main(ctx):
        ctx.submit(t1)
        ctx.submit(t2, deps=[(edat.SELF, "go")])

    run(1, main, workers=1)
    assert res["acquired"] is True


def test_timer_event():
    got = []

    def t(ctx, events):
        got.append(time.monotonic())

    def main(ctx):
        if ctx.rank == 0:
            t0 = time.monotonic()
            got.append(t0)
            ctx.fire_after(0.1, edat.SELF, "tick")
            ctx.submit(t, deps=[(edat.SELF, "tick")])

    run(1, main)
    assert got[1] - got[0] >= 0.09


def test_rank_failure_event_and_drop():
    seen = []

    def on_fail(ctx, events):
        seen.append((ctx.rank, events[0].data))

    def main(ctx):
        ctx.submit(on_fail, deps=[(edat.ANY, edat.RANK_FAILED)])

    s = edat.Session(3, workers_per_rank=1)

    def main2(ctx):
        main(ctx)
        if ctx.rank == 0:
            time.sleep(0.1)
            s.runtime.kill_rank(2)

    s.run(main2, timeout=30)
    assert sorted(seen) == [(0, 2), (1, 2)]


def test_worker_poll_progress_mode():
    """Paper §II.F: progress polling mapped onto idle workers."""
    got = []

    def t(ctx, events):
        got.append(events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(t, deps=[(1, "e")])
        else:
            ctx.fire(0, "e", 5)

    run(2, main, progress="worker")
    assert got == [5]


def test_stress_many_events_many_tasks():
    N = 300
    got = []

    def t(ctx, events):
        got.append(events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(t, deps=[(edat.ANY, "e")])
        else:
            for i in range(N):
                ctx.fire(0, "e", (ctx.rank, i))

    run(4, main, workers=2, timeout=60)
    assert len(got) == 3 * N
    # per-source FIFO preserved even under interleaving
    for r in (1, 2, 3):
        idx = [i for (src, i) in got if src == r]
        assert idx == sorted(idx)
