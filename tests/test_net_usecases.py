"""The paper's use-cases running *distributed* through the v2 Session
API: Graph500 BFS and MONC in-situ analytics across real spawned OS
processes over the coalescing SocketTransport.

Acceptance-grade checks:

* distributed BFS parent arrays are **identical** to the in-proc BSP
  reference (both resolve same-level parent claims in rank order, so the
  trees match bitwise) across multiple seeds and rank counts;
* a rank SIGKILLed mid-traversal terminates every survivor through the
  RANK_FAILED fail-stop path — no hang to the join deadline;
* the distributed analytics pipeline reduces every (field, timestep)
  exactly once.
"""
import dataclasses
import time

import numpy as np
import pytest

import _chaos as chaos
from repro import edat
from repro.analytics import InsituCfg, insitu_program
from repro.graph import (ReferenceBFS, bfs_program, build_csr,
                         default_root, kronecker_edges)

pytestmark = pytest.mark.timeout(300)


@pytest.mark.parametrize("seed,n_ranks", [(5, 2), (11, 3), (23, 4)])
def test_distributed_bfs_matches_bsp_reference(seed, n_ranks):
    """2-4 spawned processes; parent array must equal the BSP reference
    bitwise (not just same reachable set) on Kronecker graphs."""
    scale, edgefactor = 8, 8
    root = default_root(scale, edgefactor, seed)
    with edat.Session(n_ranks, transport="socket", timeout=120) as s:
        s.run(edat.deferred(bfs_program, n_ranks, scale,
                            edgefactor=edgefactor, seed=seed, root=root))
        res = s.gather()
        stats = s.stats
    parent = res["parent"]
    traversed = int(np.sum(res["traversed"]))
    edges = kronecker_edges(scale, edgefactor, seed)
    csr = build_csr(edges, 1 << scale, n_ranks)
    ref = ReferenceBFS(csr).run(root)
    assert np.array_equal(parent, ref)
    assert traversed > 0 and stats["run_seconds"] > 0


def test_distributed_bfs_rank_kill_terminates_via_rank_failed(tmp_path):
    """SIGKILL a rank mid-traversal: the victim's visit task stalls (so
    the BFS is provably in flight), the driver kills it through the
    Session, and every survivor must exit promptly through the
    RANK_FAILED fail-stop task — not hang inside the ALL-dependency
    until the join deadline."""
    ready = str(tmp_path / "ready")
    with edat.Session(3, transport="socket", timeout=60,
                      hb_interval=0.2, hb_timeout=1.5) as s:
        s.start(edat.deferred(bfs_program, 3, 8, edgefactor=8, seed=5,
                              root=1, stall=(1, 2, 300.0),
                              ready_path=ready))
        t0 = chaos.sigkill_when_ready(s, 1, ready, timeout=60, settle=0.2)
        s.wait(60, check=False)
        took = time.monotonic() - t0
        codes = s.exitcodes()
    assert codes[1] != 0                       # the victim
    # survivors exited by themselves (EdatTaskError from the fail-stop
    # task), well before the 60s straggler deadline would have killed them
    assert codes[0] not in (None,) and codes[2] not in (None,)
    assert codes[0] != 0 and codes[2] != 0     # aborted, not clean exit
    assert took < 45, f"survivors only died at the deadline ({took:.1f}s)"


def test_distributed_insitu_reduces_every_timestep():
    cfg = InsituCfg(n_analytics=2, items_per_producer=16, field_elems=128,
                    n_fields=2)
    with edat.Session(2 * cfg.n_analytics, transport="socket",
                      timeout=180, workers_per_rank=4) as s:
        s.run(edat.deferred(insitu_program, dataclasses.asdict(cfg)))
        summary = s.gather()
        stats = s.stats
    assert summary["results"] == cfg.items_per_producer
    assert summary["mean_latency_s"] > 0
    assert stats["run_seconds"] > 0
