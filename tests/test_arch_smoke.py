"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness; plus a decode-vs-prefill
consistency check for each cache family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduce_cfg
from repro.models import build_model

ARCH_NAMES = sorted(ARCHS.keys())


def tiny_batch(model, cfg, key, B=2, S=32):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (B, 8, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio":
        batch["frame_embeds"] = jax.random.normal(
            ks[2], (B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_and_grad_step(name):
    spec = ARCHS[name]
    cfg = reduce_cfg(spec.cfg)
    if cfg.frontend == "vision":
        cfg = cfg.replace(n_frontend_tokens=8)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = tiny_batch(model, cfg, key)

    def loss_fn(p):
        loss, metrics = model.loss(p, batch)
        return loss, metrics

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{name}: loss not finite"
    # a random-init model should be near ln(V) cross-entropy
    assert 0.2 * np.log(cfg.vocab) < float(metrics["ce"]) < 3.0 * np.log(cfg.vocab)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_prefill(name):
    """Greedy decode logits must match teacher-forced forward logits."""
    spec = ARCHS[name]
    cfg = reduce_cfg(spec.cfg).replace(frontend="none", n_frontend_tokens=0)
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)

    if cfg.encdec:
        frames = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        enc_out = model.encode(params, frames)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        full_lg, _, _ = model.decode(params, tokens, enc_out,
                                     positions=positions)
        caches = model.init_cache(B, S)
        lg_pre, state = model.prefill(params, tokens[:, :S - 1], caches,
                                      frame_embeds=frames)
        step_lg, _ = model.decode_step(
            params, state, tokens[:, S - 1:],
            jnp.full((B, 1), S - 1, jnp.int32))
        np.testing.assert_allclose(np.asarray(step_lg[:, 0]),
                                   np.asarray(full_lg[:, -1]),
                                   rtol=2e-4, atol=2e-4)
        return

    # teacher-forced full forward
    x = model.embed(params, tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h, _, _ = model.forward(params, x, positions=positions)
    full_lg = model.logits(params, h)

    # prefill S-1 tokens then decode the last one
    caches = model.init_cache(B, S)
    _, caches = model.prefill(params, tokens[:, :S - 1], caches)
    step_lg, _ = model.decode_step(params, caches, tokens[:, S - 1:],
                                   jnp.full((B, 1), S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(step_lg[:, 0]),
                               np.asarray(full_lg[:, -1]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_near_published(name):
    """Full-size ArchSpec parameter counts vs published sizes (abstract
    shapes only — nothing is allocated)."""
    spec = ARCHS[name]
    if spec.published_params is None:
        pytest.skip("no published count")
    model = build_model(spec.cfg)
    abstract = model.abstract_params()
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(abstract))
    rel = abs(n - spec.published_params) / spec.published_params
    assert rel < spec.param_tolerance, (
        f"{name}: {n/1e9:.2f}B vs published {spec.published_params/1e9:.2f}B "
        f"(rel err {rel:.1%})")
